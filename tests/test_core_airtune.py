"""AirTune search: optimality vs brute force, paper-claim validations,
and the registry-driven baseline families (registration, wrapper parity,
in-search dominance — hypothesis-based invariants live in
test_baselines.py)."""
import numpy as np
import pytest

from repro.core import (AffineProfile, KeyPositions, PROFILES, airtune,
                        brute_force, build_gband, build_gstep,
                        expected_latency, ideal_latency_with_index,
                        make_builders, mean_read_volume, step_index_complexity,
                        tau_hat, verify_lookup)
from repro.core.baselines import (BASELINE_FAMILIES, BTREE_PAGE_BYTES,
                                  PGM_EPS_GRID, PGM_RECORD_BYTES,
                                  btree_fanout, build_btree_layer,
                                  build_btree_multi, build_fixed_btree,
                                  build_pgm, build_pgm_layer, build_pgm_multi,
                                  build_rmi_leaf, data_calculator,
                                  homogeneous_airtune, pgm_builders,
                                  rmi_models_for_lam, tune_pgm, tune_rmi)
from repro.core.builders import _fit_bands_for_groups, fit_bands_for_groups
from repro.core.registry import BUILDER_FAMILIES, MULTI_LAM_FAMILIES

from conftest import make_keys


SMALL_BUILDERS = make_builders(lam_low=2**8, lam_high=2**16, base=4.0)


def _data(kind="gmm", n=20_000, seed=3):
    return KeyPositions.fixed_record(make_keys(kind, n, seed), 16)


def test_airtune_cost_matches_eq6_evaluator():
    D = _data()
    for pname in ("azure_ssd", "azure_nfs", "cloud_ex"):
        res = airtune(D, PROFILES[pname], SMALL_BUILDERS, k=3)
        ev = expected_latency(res.design, PROFILES[pname])
        assert ev == pytest.approx(res.cost, rel=1e-9)


def test_airtune_matches_brute_force_small():
    """Top-k pruning must not lose the optimum on a tractable space."""
    D = _data(n=3_000)
    builders = make_builders(lam_low=2**10, lam_high=2**16, base=8.0)
    for pname in ("azure_ssd", "azure_nfs"):
        prof = PROFILES[pname]
        bf = brute_force(D, prof, builders, max_layers=3)
        at = airtune(D, prof, builders, k=len(builders))  # k = |F|: no pruning
        assert at.cost == pytest.approx(bf.cost, rel=1e-9)
        pruned = airtune(D, prof, builders, k=3)
        # pruned search may differ but never by much on these spaces
        assert pruned.cost <= bf.cost * 1.05


def test_airtune_beats_or_matches_baselines():
    """§7.2-analog under the storage model (the paper's Eq. 6 objective)."""
    for kind in ("gmm", "books", "uniform"):
        D = _data(kind)
        for pname in ("azure_ssd", "azure_nfs"):
            prof = PROFILES[pname]
            ours = airtune(D, prof, k=5).cost
            for name, base_cost in [
                ("btree", expected_latency(build_fixed_btree(D), prof)),
                ("rmi", tune_rmi(D, prof).cost),
                ("pgm", tune_pgm(D, prof).cost),
                ("datacalc", data_calculator(D, prof).cost),
            ]:
                assert ours <= base_cost * 1.0001, (kind, pname, name)


def test_heterogeneous_beats_homogeneous():
    """§2.2: tuned heterogeneous ≤ best homogeneous (step-only, band-only)."""
    D = _data("gmm", n=30_000)
    prof = PROFILES["azure_ssd"]
    full = airtune(D, prof, k=5).cost
    step_only = homogeneous_airtune(D, prof, "step", k=5).cost
    band_only = homogeneous_airtune(D, prof, "band", k=5).cost
    assert full <= step_only * 1.0001
    assert full <= band_only * 1.0001


def test_adaptivity_trend():
    """Fig. 13: higher latency/bandwidth ⇒ fewer layers & more read volume;
    the extreme ⇒ no index at all."""
    D = _data(n=10_000)
    # latency-dominated extreme (Fig. 13 top-right): fetching everything in
    # one read beats paying the per-read latency of any index traversal
    slow = AffineProfile(10.0, 1e9)
    res = airtune(D, slow, SMALL_BUILDERS, k=3)
    assert res.design.n_layers == 0

    fast = AffineProfile(1e-7, 1e9)       # very fast: tall index pays off
    res_fast = airtune(D, fast, SMALL_BUILDERS, k=3)
    res_nfs = airtune(D, PROFILES["azure_nfs"], SMALL_BUILDERS, k=3)
    assert res_fast.design.n_layers >= res_nfs.design.n_layers
    assert mean_read_volume(res_fast.design) <= mean_read_volume(res_nfs.design)


def test_stopping_criterion():
    D = _data(n=50)  # tiny collection: ideal layer can't beat direct read
    prof = PROFILES["azure_nfs"]
    assert float(prof(D.size_bytes)) < ideal_latency_with_index(prof)
    res = airtune(D, prof, SMALL_BUILDERS, k=3)
    assert res.design.n_layers == 0


def test_tau_hat_is_lower_bound_to_achieved():
    """τ̂ bounds the best achievable cost from below? No — it upper-bounds
    the *ideal* index complexity τ; any REAL design costs ≥ τ.  We check the
    usable property: achieved cost ≥ τ̂'s ideal-step value at L chosen with
    real node sizes is consistent, and τ̂ ≤ cost of every built design."""
    D = _data(n=20_000)
    for pname in ("azure_ssd", "azure_nfs"):
        prof = PROFILES[pname]
        res = airtune(D, prof, SMALL_BUILDERS, k=5)
        assert tau_hat(D, prof) <= res.cost * (1 + 1e-9)


def test_tau_hat_monotone_in_size():
    prof = PROFILES["azure_ssd"]
    sizes = [2**s for s in range(8, 34, 2)]
    vals = [step_index_complexity(s, prof) for s in sizes]
    assert all(a <= b + 1e-12 for a, b in zip(vals, vals[1:]))


def test_end_to_end_lookup_valid():
    rng = np.random.default_rng(0)
    for kind in ("gmm", "fb"):
        D = _data(kind)
        res = airtune(D, PROFILES["azure_ssd"], k=5)
        qs = rng.choice(D.keys, 2_000)
        assert verify_lookup(res.design, qs)


# ---------------------------------------------------------------------------
# registry-driven baseline families (§7.1 / Appendix B in-framework)
# ---------------------------------------------------------------------------
def test_baseline_families_are_registered():
    for fam in BASELINE_FAMILIES:
        assert fam in BUILDER_FAMILIES
    # fused λ-columns for btree/pgm; rmi_leaf deliberately stays on the
    # per-λ fallback and instead canonicalizes λ → model count
    assert "btree" in MULTI_LAM_FAMILIES and "pgm" in MULTI_LAM_FAMILIES
    assert "rmi_leaf" not in MULTI_LAM_FAMILIES
    assert callable(getattr(BUILDER_FAMILIES.get("rmi_leaf"),
                            "canonical_lam", None))
    # selectable by name on the Eq. (8) grid
    F = make_builders(lam_low=2**10, lam_high=2**12, kinds=BASELINE_FAMILIES)
    assert {f.kind for f in F} == set(BASELINE_FAMILIES)
    for b in F:
        assert b.name.startswith(b.kind)


def test_fit_bands_for_groups_is_public_with_alias():
    """Satellite fix: the band-fitting helper is public API now; the old
    underscore name survives as an alias."""
    assert _fit_bands_for_groups is fit_bands_for_groups
    D = _data(n=500)
    starts = np.array([0, 100, 300], dtype=np.int64)
    layer = fit_bands_for_groups(D, starts)
    layer.validate_against(D)
    assert layer.n_nodes == 3


def test_btree_wrapper_routes_through_family():
    D = _data(n=4_000)
    default = build_fixed_btree(D)
    via_family = build_btree_layer(D, BTREE_PAGE_BYTES, 0)
    ref = build_gstep(D, p=255, lam=4096.0)       # the paper's exact B-TREE
    assert btree_fanout(BTREE_PAGE_BYTES) == 255
    for a in (default.layers[0], via_family):
        assert np.array_equal(a.piece_keys, ref.piece_keys)
        assert np.array_equal(a.piece_pos, ref.piece_pos)
        assert np.array_equal(a.node_piece_off, ref.node_piece_off)
    # explicit p keeps the legacy decoupled (p, λ) shape
    legacy = build_fixed_btree(D, p=8, lam=4096.0)
    assert np.array_equal(legacy.layers[0].node_piece_off,
                          build_gstep(D, p=8, lam=4096.0).node_piece_off)


def test_pgm_wrapper_routes_through_family():
    D = _data(n=4_000)
    for eps in (16, 256):
        d = build_pgm(D, eps)
        ref = build_gband(D, 2.0 * eps * PGM_RECORD_BYTES)
        assert np.array_equal(d.layers[0].node_keys, ref.node_keys)
        assert np.array_equal(d.layers[0].delta, ref.delta)
    lams = {b.lam for b in pgm_builders()}
    assert lams == {float(e * PGM_RECORD_BYTES) for e in PGM_EPS_GRID}


def test_rmi_models_for_lam_sweeps_n():
    D = _data(n=4_000)
    ns = [rmi_models_for_lam(D, 2.0**s) for s in range(8, 21)]
    assert all(a >= b for a, b in zip(ns, ns[1:]))  # coarser λ → fewer models
    assert ns[-1] == 1 and ns[0] > 1
    leaf = BUILDER_FAMILIES.get("rmi_leaf")(D, 2.0**12, 0)
    assert np.array_equal(leaf.node_keys,
                          build_rmi_leaf(D, rmi_models_for_lam(D, 2.0**12))
                          .node_keys)


def test_baseline_multi_lam_builds_match_single():
    """Each multi-λ element is bit-identical to the single-λ build; λ
    values resolving to the same structure share one object."""
    D = _data(n=4_000)
    lams = [2.0**s for s in range(8, 21, 2)]
    bt = build_btree_multi(D, lams, 0)
    for g, lam in zip(bt, lams):
        w = build_btree_layer(D, lam, 0)
        assert np.array_equal(g.piece_keys, w.piece_keys)
        assert np.array_equal(g.node_piece_off, w.node_piece_off)
    pg = build_pgm_multi(D, lams, 0)
    for g, lam in zip(pg, lams):
        w = build_pgm_layer(D, lam, 0)
        assert np.array_equal(g.node_keys, w.node_keys)
        assert np.array_equal(g.delta, w.delta)
    # the grid saturates on this extent: some λs must share an object
    assert len({id(x) for x in pg}) < len(pg)


def test_union_search_dominates_each_baseline_family():
    """§7.2 strict containment: brute force over the union family set can
    only beat brute force restricted to any single baseline family."""
    D = _data(n=3_000)
    kw = dict(lam_low=2**10, lam_high=2**16, base=8.0)
    for pname in ("azure_ssd", "azure_nfs"):
        prof = PROFILES[pname]
        union = brute_force(
            D, prof, make_builders(kinds=("gstep", "gband", "eband")
                                   + BASELINE_FAMILIES, **kw), max_layers=3)
        for fam in BASELINE_FAMILIES:
            alone = brute_force(D, prof,
                                make_builders(kinds=(fam,), **kw),
                                max_layers=3)
            assert union.cost <= alone.cost * (1 + 1e-12), (pname, fam)


def test_airtune_with_baselines_beats_legacy_tuners():
    """Guided search over the union set still beats the legacy fixed-shape
    tuners (benchmarks/baseline_bench.py's dominance property, in
    miniature)."""
    D = _data(n=8_000)
    prof = PROFILES["azure_ssd"]
    builders = make_builders(lam_low=2**8, lam_high=2**18,
                             kinds=("gstep", "gband", "eband")
                             + BASELINE_FAMILIES)
    ours = airtune(D, prof, builders, k=5).cost
    assert ours <= expected_latency(build_fixed_btree(D), prof) * 1.0001
    assert ours <= min(expected_latency(build_pgm(D, e), prof)
                       for e in PGM_EPS_GRID) * 1.0001


def test_pgm_eps_grid_builders_search_end_to_end():
    """The paper's exact ε grid is a usable candidate set on its own."""
    D = _data(n=20_000)
    res = airtune(D, PROFILES["azure_ssd"], pgm_builders(), k=3)
    assert res.design.n_layers >= 1
    assert all(n.startswith("pgm(") for n in res.builder_names)
    assert res.cost == pytest.approx(
        expected_latency(res.design, PROFILES["azure_ssd"]), rel=1e-9)
    assert verify_lookup(res.design, D.keys[::17])
