"""AirTune search: optimality vs brute force, paper-claim validations."""
import numpy as np
import pytest

from repro.core import (AffineProfile, KeyPositions, PROFILES, airtune,
                        brute_force, expected_latency, ideal_latency_with_index,
                        make_builders, mean_read_volume, step_index_complexity,
                        tau_hat, verify_lookup)
from repro.core.baselines import (build_fixed_btree, data_calculator,
                                  homogeneous_airtune, tune_pgm, tune_rmi)

from conftest import make_keys


SMALL_BUILDERS = make_builders(lam_low=2**8, lam_high=2**16, base=4.0)


def _data(kind="gmm", n=20_000, seed=3):
    return KeyPositions.fixed_record(make_keys(kind, n, seed), 16)


def test_airtune_cost_matches_eq6_evaluator():
    D = _data()
    for pname in ("azure_ssd", "azure_nfs", "cloud_ex"):
        res = airtune(D, PROFILES[pname], SMALL_BUILDERS, k=3)
        ev = expected_latency(res.design, PROFILES[pname])
        assert ev == pytest.approx(res.cost, rel=1e-9)


def test_airtune_matches_brute_force_small():
    """Top-k pruning must not lose the optimum on a tractable space."""
    D = _data(n=3_000)
    builders = make_builders(lam_low=2**10, lam_high=2**16, base=8.0)
    for pname in ("azure_ssd", "azure_nfs"):
        prof = PROFILES[pname]
        bf = brute_force(D, prof, builders, max_layers=3)
        at = airtune(D, prof, builders, k=len(builders))  # k = |F|: no pruning
        assert at.cost == pytest.approx(bf.cost, rel=1e-9)
        pruned = airtune(D, prof, builders, k=3)
        # pruned search may differ but never by much on these spaces
        assert pruned.cost <= bf.cost * 1.05


def test_airtune_beats_or_matches_baselines():
    """§7.2-analog under the storage model (the paper's Eq. 6 objective)."""
    for kind in ("gmm", "books", "uniform"):
        D = _data(kind)
        for pname in ("azure_ssd", "azure_nfs"):
            prof = PROFILES[pname]
            ours = airtune(D, prof, k=5).cost
            for name, base_cost in [
                ("btree", expected_latency(build_fixed_btree(D), prof)),
                ("rmi", tune_rmi(D, prof).cost),
                ("pgm", tune_pgm(D, prof).cost),
                ("datacalc", data_calculator(D, prof).cost),
            ]:
                assert ours <= base_cost * 1.0001, (kind, pname, name)


def test_heterogeneous_beats_homogeneous():
    """§2.2: tuned heterogeneous ≤ best homogeneous (step-only, band-only)."""
    D = _data("gmm", n=30_000)
    prof = PROFILES["azure_ssd"]
    full = airtune(D, prof, k=5).cost
    step_only = homogeneous_airtune(D, prof, "step", k=5).cost
    band_only = homogeneous_airtune(D, prof, "band", k=5).cost
    assert full <= step_only * 1.0001
    assert full <= band_only * 1.0001


def test_adaptivity_trend():
    """Fig. 13: higher latency/bandwidth ⇒ fewer layers & more read volume;
    the extreme ⇒ no index at all."""
    D = _data(n=10_000)
    # latency-dominated extreme (Fig. 13 top-right): fetching everything in
    # one read beats paying the per-read latency of any index traversal
    slow = AffineProfile(10.0, 1e9)
    res = airtune(D, slow, SMALL_BUILDERS, k=3)
    assert res.design.n_layers == 0

    fast = AffineProfile(1e-7, 1e9)       # very fast: tall index pays off
    res_fast = airtune(D, fast, SMALL_BUILDERS, k=3)
    res_nfs = airtune(D, PROFILES["azure_nfs"], SMALL_BUILDERS, k=3)
    assert res_fast.design.n_layers >= res_nfs.design.n_layers
    assert mean_read_volume(res_fast.design) <= mean_read_volume(res_nfs.design)


def test_stopping_criterion():
    D = _data(n=50)  # tiny collection: ideal layer can't beat direct read
    prof = PROFILES["azure_nfs"]
    assert float(prof(D.size_bytes)) < ideal_latency_with_index(prof)
    res = airtune(D, prof, SMALL_BUILDERS, k=3)
    assert res.design.n_layers == 0


def test_tau_hat_is_lower_bound_to_achieved():
    """τ̂ bounds the best achievable cost from below? No — it upper-bounds
    the *ideal* index complexity τ; any REAL design costs ≥ τ.  We check the
    usable property: achieved cost ≥ τ̂'s ideal-step value at L chosen with
    real node sizes is consistent, and τ̂ ≤ cost of every built design."""
    D = _data(n=20_000)
    for pname in ("azure_ssd", "azure_nfs"):
        prof = PROFILES[pname]
        res = airtune(D, prof, SMALL_BUILDERS, k=5)
        assert tau_hat(D, prof) <= res.cost * (1 + 1e-9)


def test_tau_hat_monotone_in_size():
    prof = PROFILES["azure_ssd"]
    sizes = [2**s for s in range(8, 34, 2)]
    vals = [step_index_complexity(s, prof) for s in sizes]
    assert all(a <= b + 1e-12 for a, b in zip(vals, vals[1:]))


def test_end_to_end_lookup_valid():
    rng = np.random.default_rng(0)
    for kind in ("gmm", "fb"):
        D = _data(kind)
        res = airtune(D, PROFILES["azure_ssd"], k=5)
        qs = rng.choice(D.keys, 2_000)
        assert verify_lookup(res.design, qs)
