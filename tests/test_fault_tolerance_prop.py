"""Property test: recoverable storage faults are invisible — for any
seeded schedule of transient EIO / torn reads / corrupt pages / stalls
(with retries enabled), lookup results AND final cache contents are
bit-identical to the fault-free run, and the tainted (retried/repaired/
stalled) read samples never leak into the measured tier fit that
``observed_profile()`` builds on."""
import dataclasses

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="optional test dep (pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.api import RetryPolicy, ServeSpec              # noqa: E402
from repro.core import KeyPositions, write_index          # noqa: E402
from repro.serve import (FaultInjectingBackend,           # noqa: E402
                         FileBackend)
from repro.serve.index_service import (IndexService,      # noqa: E402
                                       demo_serving_design,
                                       measured_backing_profile)

from conftest import make_keys                            # noqa: E402

P = 1024
_KEYS = make_keys("books", 60_000, seed=29)
_D = KeyPositions.fixed_record(_KEYS, 16)
_SPEC = ServeSpec(cache_bytes=(64 << 10,),
                  retry=RetryPolicy(max_attempts=4, backoff_s=1e-5,
                                    max_backoff_s=1e-4))


def _cache_pages(svc):
    return {pid: data for t in svc.cache.tiers for pid, data in t.items()}


@pytest.fixture(scope="module")
def baseline(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("ftprop") / "index.air")
    write_index(path, demo_serving_design(_D), page_bytes=P)
    qs = np.random.default_rng(5).choice(_KEYS, 500)
    with IndexService(path, profile=None, spec=_SPEC) as svc:
        want = svc.lookup(qs)
        pages = _cache_pages(svc)
        meta_end = min(lm.offset for lm in svc.meta.layers)
    return path, qs, want, pages, meta_end


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16),
       eio=st.floats(0.0, 0.5),
       short=st.floats(0.0, 0.5),
       corrupt=st.floats(0.0, 1.0),
       stall=st.floats(0.0, 0.3))
def test_recoverable_faults_are_invisible(baseline, seed, eio, short,
                                          corrupt, stall):
    path, qs, want, pages, meta_end = baseline
    # every fault kind bounded under the retry budget (attempts < 4);
    # corruption gated to multi-page reads so the engine's single-page
    # repair refetch comes back clean; faults gated past the meta region
    # so a dense schedule cannot spend the whole budget inside the header
    # parse before a single data page is served
    with IndexService(path, profile=None, spec=_SPEC,
                      backend_factory=lambda p: FaultInjectingBackend(
                          FileBackend(p), seed=seed, page_bytes=P,
                          eio_rate=eio, eio_attempts=2,
                          short_rate=short, short_attempts=1,
                          corrupt_rate=corrupt, corrupt_attempts=1,
                          stall_rate=stall, stall_seconds=1e-4,
                          stall_attempts=1,
                          only_over_bytes=P if corrupt else 0,
                          only_from_offset=meta_end)) as svc:
        got = svc.lookup(qs)
        stats = svc.stats
        faulted_pages = _cache_pages(svc)
    assert np.array_equal(want, got)
    assert faulted_pages == pages
    # the measured tier fit sees only clean samples: stripping tainted
    # ones by hand must change nothing
    clean_only = dataclasses.replace(
        stats, read_samples=[r for r in stats.read_samples if not r[3]])
    assert measured_backing_profile(stats, min_samples=2) == \
        measured_backing_profile(clean_only, min_samples=2)
