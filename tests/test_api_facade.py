"""repro.api facade: TuneSpec JSON round-trip, registry liveness in Alg. 2,
Index lifecycle (tune → save → open → serve) parity, strategy protocol,
deprecation shims."""
import json

import numpy as np
import pytest

from repro.api import (BUILDER_FAMILIES, Index, SEARCH_STRATEGIES, TuneSpec,
                       register_builder, register_strategy)
from repro.core import (KeyPositions, PROFILES, airtune, beam_search,
                        brute_force, build_eband, load_index, lookup_batch,
                        make_builders, verify_lookup)
from repro.core.lookup import lookup_file
from repro.core.serialize import lookup_serialized

from conftest import make_keys

SPEC = TuneSpec(lam_high=2.0**16, lam_base=4.0, k=3, max_layers=4,
                page_bytes=1024, cache_bytes=(64 << 10, 256 << 10))


def _data(kind="gmm", n=20_000, seed=3):
    return KeyPositions.fixed_record(make_keys(kind, n, seed), 16)


# ---------------------------------------------------------------------------
# TuneSpec: declarative, JSON-round-trippable, registry-validated
# ---------------------------------------------------------------------------
def test_tunespec_json_roundtrip():
    for spec in (TuneSpec(), SPEC,
                 TuneSpec(families=("gband",), strategy="beam", k=7)):
        assert TuneSpec.from_json(spec.to_json()) == spec
        assert TuneSpec.from_dict(spec.to_dict()) == spec
        # the wire form is plain JSON (usable from configs / other languages)
        d = json.loads(spec.to_json())
        assert isinstance(d["families"], list)


def test_tunespec_rejects_unknown_fields_and_names():
    with pytest.raises(ValueError, match="unknown TuneSpec fields"):
        TuneSpec.from_dict({"lambda_low": 17})
    err = pytest.raises(KeyError, TuneSpec(families=("gstep", "nope")).validate)
    assert "nope" in str(err.value) and "gstep" in str(err.value), \
        "KeyError must list registered builder families"
    err = pytest.raises(KeyError, TuneSpec(strategy="nope").validate)
    assert "airtune" in str(err.value) and "beam" in str(err.value), \
        "KeyError must list registered strategies"


def test_tunespec_validate_raises_on_bad_numbers():
    # real ValueErrors (not asserts): these must hold under `python -O` too
    with pytest.raises(ValueError, match="grid"):
        TuneSpec(lam_base=0.5).validate()
    with pytest.raises(ValueError, match="knobs"):
        TuneSpec(k=0).validate()
    with pytest.raises(ValueError, match="family"):
        TuneSpec(families=()).validate()


def test_open_tolerates_forward_version_spec(tmp_path):
    """Provenance from a newer version (unknown TuneSpec fields) must not
    make a readable file unopenable — spec degrades to None, lookups work."""
    from repro.core import write_index
    from repro.serve.index_service import demo_serving_design
    D = _data(n=5_000)
    path = str(tmp_path / "fwd.air")
    future_spec = dict(TuneSpec().to_dict(), from_the_future=True)
    write_index(path, demo_serving_design(D), page_bytes=1024,
                tune={"spec": future_spec, "strategy": "airtune",
                      "cost": 1e-3, "profile": "azure_ssd"})
    with Index.open(path) as idx:
        assert idx.spec is None
        assert idx.file_meta.tune["spec"]["from_the_future"] is True
        qs = np.random.default_rng(3).choice(D.keys, 50)
        assert np.array_equal(idx.lookup(qs), lookup_serialized(path, None, qs))
        with idx.serve(profile="azure_ssd") as svc:
            assert svc.tune_spec is None       # degrades the same way
            assert np.array_equal(svc.lookup(qs)[:, 0], idx.lookup(qs)[:, 0])


def test_measured_profile_survives_save_open_serve(tmp_path):
    """A custom (non-named) T(Δ) must be restored on open, so serve() models
    the tuned-for tier — not a silent azure_ssd fallback."""
    from repro.core import MeasuredProfile
    prof = MeasuredProfile(deltas=(256, 4096, 1 << 20),
                           seconds=(1e-4, 2e-4, 2e-3), name="bench-tier")
    D = _data(n=5_000)
    path = str(tmp_path / "measured.air")
    Index.tune(D, prof, SPEC).save(path)
    re = Index.open(path)
    assert re.profile == prof            # full parameters, not just the name
    with re.serve() as svc:
        assert svc.profile == prof
    # CachedProfile (nested) round-trips too
    from repro.core import CachedProfile, profile_from_dict, profile_to_dict
    cp = CachedProfile(backing=prof, cache=PROFILES["host_dram"],
                       hit_rate=0.75)
    assert profile_from_dict(profile_to_dict(cp)) == cp


def test_describe_never_triggers_the_search():
    idx = Index.tune(_data(n=2_000), "azure_ssd", SPEC)
    assert "unbuilt" in idx.describe()
    assert idx._result is None           # formatting stayed side-effect-free
    idx.build()
    assert "[airtune]" in idx.describe()


def test_unknown_profile_lists_names():
    with pytest.raises(KeyError, match="azure_ssd"):
        Index.tune(_data(n=100), "not_a_tier")


# ---------------------------------------------------------------------------
# Index lifecycle: tune → save → open → serve round-trip (acceptance gate)
# ---------------------------------------------------------------------------
def test_lifecycle_roundtrip_bit_identical(tmp_path):
    D = _data()
    qs = np.random.default_rng(0).choice(D.keys, 500)
    path = str(tmp_path / "index.air")

    idx = Index.tune(D, "azure_ssd", SPEC)
    idx.save(path)
    assert idx.design.n_layers >= 1
    mem = lookup_batch(idx.design, qs)
    assert np.array_equal(idx.lookup(qs)[:, 0], mem.lo)

    reopened = Index.open(path)
    # the file remembers how it was tuned
    assert reopened.spec == SPEC
    assert reopened.file_meta.tune["strategy"] == "airtune"
    assert reopened.file_meta.tune["profile"] == "azure_ssd"
    assert reopened.file_meta.page_bytes == SPEC.page_bytes

    # served lookups are bit-identical to the in-memory design's
    with reopened.serve() as svc:
        assert svc.profile == PROFILES["azure_ssd"]    # tuned-for default
        assert svc.cache.cap_pages == [c // svc.page_bytes
                                       for c in SPEC.cache_bytes]
        got = svc.lookup(qs)
    assert np.array_equal(got[:, 0], mem.lo)
    assert np.array_equal(got[:, 1], mem.hi)
    # ... and so is the facade's own disk walk
    with Index.open(path) as disk:
        assert np.array_equal(disk.lookup(qs), got)

    # materialization with data reproduces a valid design
    assert verify_lookup(Index.open(path, data=D).design, qs)


def test_baseline_families_roundtrip_provenance(tmp_path):
    """families=("btree", "pgm", "gstep") through the whole lifecycle:
    tune → build → save → open → serve round-trips, and the reopened meta
    records the baseline family names in IndexFileMeta.tune / describe()."""
    D = _data(n=15_000)
    spec = SPEC.replace(families=("btree", "pgm", "gstep"))
    path = str(tmp_path / "base.air")
    idx = Index.tune(D, "azure_ssd", spec).build()
    names = idx.result.builder_names
    assert names, "search must place at least one layer on this input"
    assert all(n.split("(")[0] in {"btree", "pgm", "gstep"} for n in names)
    idx.save(path)
    qs = np.random.default_rng(5).choice(D.keys, 300)
    mem = idx.lookup(qs)

    reopened = Index.open(path)
    assert reopened.spec == spec
    assert reopened.spec.families == ("btree", "pgm", "gstep")
    assert reopened.file_meta.tune["spec"]["families"] == \
        ["btree", "pgm", "gstep"]
    assert tuple(reopened.file_meta.tune["builder_names"]) == names
    # describe() surfaces the provenance without touching the search
    desc = reopened.describe()
    assert "btree" in desc and "pgm" in desc and names[0] in desc
    with reopened.serve() as svc:
        got = svc.lookup(qs)
    assert np.array_equal(got[:, 0], mem[:, 0])
    assert np.array_equal(got[:, 1], mem[:, 1])
    with Index.open(path) as disk:
        assert np.array_equal(disk.lookup(qs), got)
    # materialization with data reproduces a valid design
    assert verify_lookup(Index.open(path, data=D).design, qs)


def test_disk_opened_index_never_resarches(tmp_path):
    """Opening a file must not silently re-run the search: the file IS the
    design, and cost/strategy come from the recorded provenance."""
    from repro.serve.index_service import demo_serving_design
    D = _data(n=10_000)
    idx = Index.from_design(demo_serving_design(D), spec=SPEC,
                            profile="azure_ssd")
    path = str(tmp_path / "d.air")
    idx.save(path)

    re = Index.open(path, data=D)
    assert re.design.n_layers == 3                    # the file's design...
    assert re.cost == pytest.approx(idx.cost)         # ...and its recorded cost
    assert re.design.n_layers == 3                    # still, after .cost
    assert re.build() is re                           # no-op, not a search
    with pytest.raises(ValueError, match="opened from disk"):
        _ = re.result
    with pytest.raises(ValueError, match="opened from disk"):
        re.save(str(tmp_path / "clobber.air"))
    assert "strategy=manual" in re.describe()
    # an explicit re-search is the retune() call, never an attribute access
    fresh = re.retune("azure_ssd", data=D)
    assert fresh.path is None and fresh.result.strategy == "airtune"


def test_save_without_profile_writes_strict_json(tmp_path):
    """An unknown cost (from_design without profile) must not leak NaN into
    the on-disk JSON header."""
    from repro.serve.index_service import demo_serving_design
    D = _data(n=5_000)
    path = str(tmp_path / "nan.air")
    Index.from_design(demo_serving_design(D), spec=SPEC).save(path)
    import os
    with open(path, "rb") as f:
        head = f.read(16)
        hlen = int(np.frombuffer(head, dtype="<u8")[1])
        raw = f.read(hlen).decode()
    def _no_const(x):
        raise ValueError(f"non-strict JSON constant {x}")
    meta = json.loads(raw, parse_constant=_no_const)   # strict parse
    assert meta["tune"]["cost"] is None
    assert np.isnan(Index.open(path).cost)
    assert os.path.getsize(path) > hlen


def test_save_page_bytes_override_recorded_in_spec(tmp_path):
    """An explicit save(page_bytes=...) must be reflected in the recorded
    provenance — the spec describes the file as written."""
    idx = Index.tune(_data(n=2_000), "azure_ssd", SPEC)
    path = str(tmp_path / "o.air")
    idx.save(path, page_bytes=2048)
    re = Index.open(path)
    assert re.file_meta.page_bytes == 2048
    assert re.spec.page_bytes == 2048
    assert re.spec == SPEC.replace(page_bytes=2048)


def test_disk_opened_lookup_uses_partial_reads(tmp_path):
    """data= on open enables materialization/retune, but lookups on a
    disk-opened Index stay on the partial-read walk."""
    D = _data(n=5_000)
    path = str(tmp_path / "pr.air")
    Index.tune(D, "azure_ssd", SPEC).save(path)
    qs = np.random.default_rng(4).choice(D.keys, 100)
    with Index.open(path, data=D) as idx:
        got = idx.lookup(qs)
        assert idx._handle is not None          # SerializedIndex walk ran
        assert idx._disk_design is None         # no full materialization
    assert np.array_equal(got, lookup_serialized(path, None, qs))


def test_open_without_data_cannot_materialize(tmp_path):
    path = str(tmp_path / "i.air")
    Index.tune(_data(n=2_000), "azure_ssd", SPEC).save(path)
    with pytest.raises(ValueError, match="data"):
        _ = Index.open(path).design


def test_retune_uses_recorded_spec():
    D = _data(n=10_000)
    idx = Index.tune(D, "azure_ssd", SPEC).build()
    re = idx.retune("azure_nfs")
    assert re.spec == SPEC
    assert re.profile is PROFILES["azure_nfs"]
    assert verify_lookup(re.design, D.keys[:100])


def test_from_design_wraps_manual_stacks(tmp_path):
    from repro.serve.index_service import demo_serving_design
    D = _data(n=15_000)
    idx = Index.from_design(demo_serving_design(D), spec=SPEC,
                            profile="azure_ssd")
    assert idx.result.strategy == "manual"
    assert np.isfinite(idx.cost)
    path = str(tmp_path / "m.air")
    idx.save(path)
    qs = np.random.default_rng(1).choice(D.keys, 200)
    assert np.array_equal(Index.open(path).lookup(qs),
                          lookup_serialized(path, None, qs))


# ---------------------------------------------------------------------------
# registry: third-party families participate in the Alg. 2 search
# ---------------------------------------------------------------------------
def test_registered_builder_selected_when_dominating():
    # perfectly linear data: one global band node (40 B, ~20 B windows)
    # strictly beats every gstep candidate (λ ≥ 256 B windows)
    D = KeyPositions.fixed_record(
        np.arange(1, 20_001, dtype=np.uint64), 16)

    @register_builder("oracleband")
    def _oracle(Dc, lam, p):
        return build_eband(Dc, 2.0**60)      # single band over everything

    try:
        with_oracle = Index.tune(
            D, "azure_ssd", SPEC, families=("gstep", "oracleband")).result
        gstep_only = Index.tune(
            D, "azure_ssd", SPEC, families=("gstep",)).result
        assert any(n.startswith("oracleband")
                   for n in with_oracle.builder_names), with_oracle
        assert with_oracle.cost < gstep_only.cost
        assert verify_lookup(with_oracle.design, D.keys[::37])
    finally:
        BUILDER_FAMILIES.unregister("oracleband")


def test_registry_rejects_duplicate_and_lists_names():
    assert set(BUILDER_FAMILIES.names()) >= {"gstep", "gband", "eband"}
    assert set(SEARCH_STRATEGIES.names()) >= {"airtune", "beam", "brute_force"}
    with pytest.raises(ValueError, match="already registered"):
        register_builder("gstep", lambda D, lam, p: None)
    with pytest.raises(KeyError, match="gband"):
        make_builders(kinds=("gstep", "missing_family"))


def test_registered_strategy_resolves_through_facade():
    calls = []

    @register_strategy("unit_probe")
    def _probe(D, profile, builders=None, *, k=5, max_layers=12):
        calls.append((k, max_layers))
        return airtune(D, profile, builders, k=k, max_layers=max_layers)

    try:
        idx = Index.tune(_data(n=2_000), "azure_ssd", SPEC,
                         strategy="unit_probe").build()
        assert calls == [(SPEC.k, SPEC.max_layers)]
        assert idx.result.strategy == "airtune"   # probe delegated
    finally:
        SEARCH_STRATEGIES.unregister("unit_probe")


# ---------------------------------------------------------------------------
# search strategies: shared protocol, stats, describe()
# ---------------------------------------------------------------------------
def test_beam_matches_brute_force_with_wide_beam():
    D = _data(n=3_000)
    builders = make_builders(lam_low=2**10, lam_high=2**16, base=8.0)
    for pname in ("azure_ssd", "azure_nfs"):
        prof = PROFILES[pname]
        bf = brute_force(D, prof, builders, max_layers=3)
        bm = beam_search(D, prof, builders, k=10_000, max_layers=3)
        assert bm.cost == pytest.approx(bf.cost, rel=1e-9)
        narrow = beam_search(D, prof, builders, k=2, max_layers=3)
        assert narrow.cost <= float(prof(D.size_bytes)) * (1 + 1e-12)
        assert verify_lookup(narrow.design, D.keys[::23])


def test_describe_reports_strategy_name():
    D = _data(n=3_000)
    builders = make_builders(lam_low=2**10, lam_high=2**16, base=8.0)
    prof = PROFILES["azure_ssd"]
    assert "[airtune]" in airtune(D, prof, builders, k=2).describe()
    assert "[beam]" in beam_search(D, prof, builders, k=2).describe()
    assert "[brute_force]" in brute_force(D, prof, builders,
                                          max_layers=2).describe()


def test_brute_force_populates_candidates_pruned():
    # 2 pairs of 16 B: any band layer (40 B/node) cannot shrink the 32 B
    # collection, so the termination safeguard must discard (and count) it
    D = KeyPositions.fixed_record(np.asarray([10, 20], dtype=np.uint64), 16)
    builders = make_builders(lam_low=2**8, lam_high=2**8, kinds=("eband",))
    res = brute_force(D, PROFILES["azure_ssd"], builders, max_layers=2)
    assert res.stats.candidates_pruned > 0
    assert res.design.n_layers == 0


# ---------------------------------------------------------------------------
# deprecation shims: warn, and return bit-identical results to the facade
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def saved(tmp_path_factory):
    D = _data(n=8_000, seed=9)
    path = str(tmp_path_factory.mktemp("shim") / "index.air")
    idx = Index.tune(D, "azure_ssd", SPEC).save(path)
    qs = np.random.default_rng(2).choice(D.keys, 200)
    return D, idx, path, qs


def test_load_index_shim_warns_and_matches(saved):
    D, idx, path, qs = saved
    with pytest.warns(DeprecationWarning, match="Index.open"):
        design = load_index(path, D)
    facade = Index.open(path, data=D).design
    a, b = lookup_batch(design, qs), lookup_batch(facade, qs)
    assert np.array_equal(a.lo, b.lo) and np.array_equal(a.hi, b.hi)


def test_lookup_file_shim_warns_and_matches(saved):
    D, idx, path, qs = saved
    with pytest.warns(DeprecationWarning, match="Index.open"):
        got = lookup_file(path, None, qs)
    with Index.open(path) as facade:
        assert np.array_equal(got, facade.lookup(qs))


def test_internal_shim_use_is_hard_error():
    """Shims are for callers, not for repro-internal code (CI gate)."""
    import types

    from repro.core import deprecation

    # a function whose __globals__ place it inside the repro package
    mod = types.ModuleType("repro._fake_internal")
    mod.warn_deprecated = deprecation.warn_deprecated
    exec("def shim():\n    warn_deprecated('nope', stacklevel=2)",
         mod.__dict__)
    with pytest.raises(AssertionError, match="within repro"):
        mod.shim()
    # the same call from this (non-repro) test module only warns
    with pytest.warns(DeprecationWarning, match="nope"):
        deprecation.warn_deprecated("nope", stacklevel=2)


# ---------------------------------------------------------------------------
# unsaved indexes refuse to serve with a clear message
# ---------------------------------------------------------------------------
def test_serve_requires_saved_file():
    idx = Index.tune(_data(n=1_000), "azure_ssd", SPEC)
    with pytest.raises(ValueError, match="save"):
        idx.serve()
