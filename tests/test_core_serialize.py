"""Serialization round-trip + real partial-read lookup (Alg. 1 on files)."""
import numpy as np
import pytest

from repro.api import Index, TuneSpec
from repro.core import (KeyPositions, PROFILES, SerializedIndex, airtune,
                        make_builders, verify_lookup, write_index)

from conftest import make_keys


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    keys = make_keys("gmm", 30_000, seed=11)
    D = KeyPositions.fixed_record(keys, 16)
    res = airtune(D, PROFILES["azure_ssd"],
                  make_builders(lam_low=2**8, lam_high=2**16, base=4.0), k=3)
    path = str(tmp_path_factory.mktemp("idx") / "index.air")
    meta = write_index(path, res.design)
    return D, res.design, path, meta


def test_serialized_sizes_match_model(built):
    D, design, path, meta = built
    for layer, lm in zip(design.layers, meta.layers):
        assert lm.size == layer.size_bytes


def test_roundtrip_predictions_match(built):
    D, design, path, meta = built
    rng = np.random.default_rng(0)
    qs = rng.choice(D.keys, 500)
    loaded = Index.open(path, data=D).design
    assert verify_lookup(loaded, qs)


def test_legacy_file_opens_without_spec(built):
    """Files written by the raw engine (no facade) have no provenance."""
    D, design, path, meta = built
    idx = Index.open(path)
    assert idx.spec is None and idx.file_meta.tune is None


def test_facade_spec_survives_the_fixpoint_header(tmp_path):
    """write_index re-encodes the JSON header until offsets stabilize; the
    tune provenance must survive that and round-trip exactly."""
    D = KeyPositions.fixed_record(make_keys("gmm", 5_000, seed=2), 16)
    spec = TuneSpec(lam_high=2.0**14, lam_base=4.0, k=2, max_layers=3,
                    page_bytes=512, cache_bytes=(32 << 10,))
    path = str(tmp_path / "p.air")
    Index.tune(D, "azure_nfs", spec).save(path)
    assert Index.open(path).spec == spec


def test_partial_read_lookup_valid_and_partial(built):
    D, design, path, meta = built
    rng = np.random.default_rng(1)
    qs = rng.choice(D.keys, 300)
    idx = SerializedIndex(path)
    try:
        kidx = np.searchsorted(D.keys, qs)
        for q, i in zip(qs, kidx):
            lo, hi = idx.lookup(int(q))
            assert lo <= D.lo[i] and hi >= D.hi[i], "file lookup violates Eq.(1)"
        # partial reads only: far less than one full-file read per query
        total_index_bytes = sum(lm.size for lm in meta.layers)
        if design.n_layers > 1:
            assert idx.bytes_read < total_index_bytes + 300 * 64 * 1024
    finally:
        idx.close()
