"""Per-arch smoke tests: reduced configs, forward + train step + decode.

Required per the assignment: every architecture instantiates a REDUCED
same-family config and runs one forward/train step on CPU asserting output
shapes and no NaNs.  Decode-vs-train logit consistency is checked for the
families where stepwise decode is exact.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import api
from repro.train import TrainConfig, adamw_init, make_train_step

RNG = jax.random.PRNGKey(0)
NP = np.random.default_rng(0)


def _batch(cfg, B=2, S=32, labels=True):
    batch = {"tokens": jnp.asarray(NP.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if labels:
        batch["labels"] = jnp.asarray(NP.integers(0, cfg.vocab, (B, S)),
                                      jnp.int32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            NP.normal(size=(B, cfg.n_patches, cfg.d_model)), cfg.jdtype)
        batch["patch_positions"] = jnp.asarray(
            NP.integers(0, S, (B, cfg.n_patches)), jnp.int32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            NP.normal(size=(B, cfg.n_frames, cfg.d_model)), cfg.jdtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, RNG)
    batch = _batch(cfg, labels=False)
    logits, aux = api.forward_train(cfg, params, batch)
    assert logits.shape[:2] == batch["tokens"].shape
    assert logits.shape[2] in (cfg.vocab, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, RNG)
    tcfg = TrainConfig()
    step = make_train_step(cfg, tcfg)
    opt = adamw_init(params, tcfg.optimizer)
    batch = _batch(cfg)
    p2, o2, m = jax.jit(step)(params, opt, batch)
    loss = float(m["loss"])
    assert np.isfinite(loss)
    # random init ⇒ loss ≈ ln(vocab)
    assert abs(loss - np.log(cfg.vocab)) < np.log(cfg.vocab)
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


def test_microbatched_step_matches_single():
    cfg = get_config("qwen3_14b", smoke=True).scaled(dtype="float32")
    params = api.init_params(cfg, RNG)
    batch = _batch(cfg, B=4)
    outs = []
    for n in (1, 2):
        tcfg = TrainConfig(microbatches=n)
        step = make_train_step(cfg, tcfg)
        opt = adamw_init(params, tcfg.optimizer)
        p2, _, m = jax.jit(step)(params, opt, batch)
        outs.append(p2)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(outs[0]),
                            jax.tree.leaves(outs[1])))
    assert d < 5e-5, d


# MoE archs are excluded: capacity dropping depends on how many tokens
# share a dispatch group, so batch prefill and stepwise decode may drop
# different tokens (by design of capacity-based routing)
DECODE_EXACT = ["deepseek_coder_33b", "qwen3_14b", "glm4_9b", "gemma2_27b",
                "rwkv6_7b", "zamba2_1p2b", "whisper_small"]


@pytest.mark.parametrize("arch", DECODE_EXACT)
def test_decode_matches_train(arch):
    cfg = get_config(arch, smoke=True).scaled(dtype="float32", remat=False)
    params = api.init_params(cfg, RNG)
    B, S, MAX = 2, 12, 16
    batch = _batch(cfg, B=B, S=S, labels=False)
    ref, _ = api.forward_train(cfg, params, batch)
    if cfg.family == "audio":
        state = api.init_decode_state(cfg, params, B, MAX,
                                      frames=batch["frames"])
    else:
        state = api.init_decode_state(cfg, params, B, MAX)
    errs = []
    for t in range(S):
        d, state = api.forward_decode(
            cfg, params, {"tokens": batch["tokens"][:, t:t + 1]}, state, t)
        errs.append(float(jnp.max(jnp.abs(d[:, 0] - ref[:, t]))))
    assert max(errs) < 5e-3, max(errs)


def test_gemma2_local_global_masks_differ():
    """The alternating pattern must actually change attention: shrinking
    the window changes logits when the sequence exceeds it."""
    cfg = get_config("gemma2_27b", smoke=True).scaled(dtype="float32")
    params = api.init_params(cfg, RNG)
    batch = _batch(cfg, S=48, labels=False)
    a, _ = api.forward_train(cfg, params, batch)
    cfg2 = cfg.scaled(sliding_window=4)
    b, _ = api.forward_train(cfg2, params, batch)
    assert float(jnp.max(jnp.abs(a - b))) > 1e-4


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor, MoE output must differ from cf=8
    (dropping is real), while cf large enough is deterministic."""
    from repro.models.layers import moe_ffn
    d, E, T = 16, 4, 64
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    router = jnp.asarray(rng.normal(size=(d, E)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(E, d, 32)) * 0.1, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(E, d, 32)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(E, 32, d)) * 0.1, jnp.float32)
    big = moe_ffn(x, router, wg, wu, wd, top_k=1, capacity_factor=8.0)
    small = moe_ffn(x, router, wg, wu, wd, top_k=1, capacity_factor=0.3)
    assert float(jnp.max(jnp.abs(big - small))) > 1e-6
    # and dropped rows are exactly zero contribution for single-expert rows
    assert bool(jnp.isfinite(big).all() and jnp.isfinite(small).all())


def test_param_count_sane():
    """Config param counts must be within 20% of actual spec byte counts."""
    for arch in ARCHS:
        cfg = get_config(arch)
        specs = api.param_specs(cfg)
        actual = sum(np.prod(s.shape) for s in jax.tree.leaves(specs))
        est = cfg.param_count()
        assert 0.7 < est / actual < 1.35, (arch, est, actual)
