"""The observe→retune loop: persisted ServeStats, observed profiles,
drift detection, and warm-started retune (ROADMAP: incremental re-tune
on drift / serve-path autoscaling)."""
import dataclasses
import os

import numpy as np
import pytest

from repro.api import (Index, ServeSpec, TuneSpec, detect_drift,
                       detect_drift_from_file)
from repro.api.drift import drift_from_stats
from repro.core import KeyPositions, PROFILES
from repro.serve.index_service import (ServeStats, demo_serving_design,
                                       load_serve_stats, load_stats_history,
                                       observed_profile_from_stats,
                                       save_stats_snapshot, stats_path)

from conftest import make_keys

SPEC = TuneSpec(lam_low=2**8, lam_high=2**15, lam_base=4.0, k=3,
                max_layers=6, page_bytes=1024,
                cache_bytes=(64 << 10, 512 << 10))


def _serve_some(svc, keys, n_batches=4, batch=200, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        svc.lookup(rng.choice(keys, batch))


@pytest.fixture(scope="module")
def tuned(tmp_path_factory):
    keys = make_keys("gmm", 60_000, seed=5)
    D = KeyPositions.fixed_record(keys, 16)
    idx = Index.tune(D, "azure_ssd", SPEC).build()
    path = str(tmp_path_factory.mktemp("drift") / "index.air")
    idx.save(path)
    return D, idx, path


# ---------------------------------------------------------------------------
# persisted ServeStats: snapshot file round-trip
# ---------------------------------------------------------------------------
def test_serve_stats_snapshot_roundtrip_and_observed_profile(tuned):
    D, idx, path = tuned
    svc = idx.serve(profile="azure_nfs", persist_stats=True)
    _serve_some(svc, D.keys)
    live_stats = dataclasses.replace(
        svc.stats, read_samples=list(svc.stats.read_samples))
    live_cached = svc.cached_profile()
    live_observed = svc.observed_profile()
    svc.close()                                    # persist_stats → snapshot

    assert os.path.exists(stats_path(path))
    loaded = load_serve_stats(path)
    # field-exact round-trip (JSON floats round-trip via repr)
    assert loaded == live_stats
    assert loaded.hit_rate == live_stats.hit_rate
    assert loaded.query_modeled_seconds == live_stats.query_modeled_seconds

    # reloaded snapshot → the SAME observed profile as the live service
    re_obs = observed_profile_from_stats(loaded, PROFILES["azure_nfs"],
                                         PROFILES["host_dram"])
    assert re_obs == live_observed
    # and with measured=False the observed profile IS cached_profile()
    re_cfg = observed_profile_from_stats(loaded, PROFILES["azure_nfs"],
                                         PROFILES["host_dram"],
                                         measured=False)
    assert re_cfg == live_cached


def test_stats_window_rotates(tuned):
    D, idx, path = tuned
    s = ServeStats(queries=1)
    for i in range(7):
        s.queries = i
        save_stats_snapshot(path, s, profile_name="azure_ssd", window=5)
    hist = load_stats_history(path)
    assert len(hist) == 5                          # rotating window
    assert [h["stats"]["queries"] for h in hist] == [2, 3, 4, 5, 6]
    assert all(h["profile"] == "azure_ssd" for h in hist)
    os.unlink(stats_path(path))                    # leave fixture clean


def test_read_samples_reservoir_is_bounded_and_uniform():
    from repro.serve.index_service import READ_SAMPLE_CAP
    n = READ_SAMPLE_CAP * 4
    s = ServeStats()
    for i in range(n):
        s.record_read(64, 1e-6 * i)
    assert len(s.read_samples) == READ_SAMPLE_CAP
    assert s.reads_seen == n
    # uniform over the whole stream, not a recency window (the old
    # cap-eviction kept only the newest READ_SAMPLE_CAP samples, which
    # biased quantile fits toward the latest burst): a fair share of the
    # retained samples must predate the final window
    old = sum(1 for r in s.read_samples if r[1] < 1e-6 * (n - READ_SAMPLE_CAP))
    assert old > READ_SAMPLE_CAP // 4
    # deterministic under a fixed seed; a different seed reshuffles
    s2 = ServeStats()
    for i in range(n):
        s2.record_read(64, 1e-6 * i)
    assert s2.read_samples == s.read_samples
    s3 = ServeStats(sample_seed=7)
    for i in range(n):
        s3.record_read(64, 1e-6 * i)
    assert s3.read_samples != s.read_samples


def test_lookup_reservoir_quantiles():
    s = ServeStats()
    assert s.lookup_quantile(0.5) is None
    # 99 fast batches and 1 slow one, single-query each
    for i in range(99):
        s.record_lookup(1, 1e-4)
    s.record_lookup(1, 1e-2)
    assert s.lookup_quantile(0.5) == pytest.approx(1e-4)
    assert s.lookup_quantile(0.995) == pytest.approx(1e-2, rel=0.5)
    # batch sizes weight the estimate: one 64-query slow batch outweighs
    # one 1-query slow batch at the same quantile
    with pytest.raises(ValueError):
        s.lookup_quantile(1.5)
    snap = s.snapshot()
    assert snap["lookup_p50_seconds"] == pytest.approx(1e-4)
    loaded = ServeStats.from_snapshot(snap)
    assert loaded.lookup_samples == s.lookup_samples
    assert loaded.lookup_quantile(0.5) == s.lookup_quantile(0.5)


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------
def test_no_drift_on_the_tuned_tier(tuned):
    D, idx, path = tuned
    with idx.serve(profile="azure_ssd") as svc:
        _serve_some(svc, D.keys)
        rep = detect_drift(svc, min_queries=256)
    # the walk prediction realizes Eq. 6 on observed traffic: ratio ≈ 1
    # regardless of how warm the cache got
    assert 0.9 < rep.ratio < 1.1
    assert rep.action == "none" and not rep.drifted
    assert rep.confidence == 1.0
    assert rep.observed_seconds <= rep.predicted_seconds * (1 + 1e-9)


def test_drift_on_a_degraded_tier(tuned):
    D, idx, path = tuned
    with idx.serve(profile="azure_hdd", persist_stats=True) as svc:
        _serve_some(svc, D.keys)
        rep = detect_drift(svc, min_queries=256)
    assert rep.drifted and rep.action == "retune"
    assert rep.ratio > 1.25
    # the recommended profile folds the observed hit rate over the tier
    assert rep.observed_profile is not None
    assert rep.observed_profile.hit_rate == rep.hit_rate

    # offline detection from the persisted snapshot agrees exactly
    off = detect_drift_from_file(path, backing="azure_hdd", min_queries=256)
    assert off is not None
    assert off.ratio == rep.ratio and off.action == rep.action
    # default backing = the profile the snapshot was SERVED under (the
    # deployment tier), not the stale tuned-for tier from the meta
    dflt = detect_drift_from_file(path, min_queries=256)
    assert dflt.observed_profile is not None
    assert dflt.observed_profile == rep.observed_profile
    os.unlink(stats_path(path))


def test_no_drift_with_extra_resident_layers():
    # non-root resident layers are WINDOW reads in the scalar walk: the
    # walk prediction must charge the record-aligned window, not the full
    # layer size, or a multi-layer index pinned in memory would read as
    # drifted on its own tuned-for tier
    keys = make_keys("gmm", 80_000, seed=7)
    D = KeyPositions.fixed_record(keys, 16)
    import tempfile
    design = demo_serving_design(D)          # 3 layers
    idx = Index.from_design(design, spec=TuneSpec(page_bytes=1024),
                            profile="azure_ssd")
    path = os.path.join(tempfile.mkdtemp(), "res.air")
    idx.save(path)
    from repro.serve import IndexService
    with IndexService(path, profile="azure_ssd",
                      spec=ServeSpec(resident_layers=3)) as svc:
        _serve_some(svc, D.keys)
        rep = detect_drift(svc, min_queries=256)
    # record-alignment overhead keeps the ratio slightly above 1, far
    # inside the drift band
    assert 0.9 < rep.ratio < 1.25
    assert rep.action == "none", rep.describe()


def test_drift_needs_enough_queries(tuned):
    D, idx, path = tuned
    with idx.serve(profile="azure_hdd") as svc:
        svc.lookup(D.keys[:8])
        rep = detect_drift(svc)                    # default MIN_QUERIES=512
    assert rep.action == "observe" and rep.confidence < 1.0


def test_drift_without_provenance_reports_observe():
    # files written without the facade have no recorded cost
    keys = make_keys("books", 30_000, seed=2)
    D = KeyPositions.fixed_record(keys, 16)
    import tempfile

    from repro.core import write_index
    path = os.path.join(tempfile.mkdtemp(), "raw.air")
    write_index(path, demo_serving_design(D), page_bytes=1024)
    from repro.serve import IndexService
    with IndexService(path, profile="azure_ssd") as svc:
        _serve_some(svc, D.keys, n_batches=3)
        rep = detect_drift(svc, min_queries=16)
    assert rep.recorded_seconds is None
    assert not np.isfinite(rep.ratio) and rep.action == "observe"


def test_drift_report_json_safe(tuned):
    import json
    D, idx, path = tuned
    with idx.serve(profile="azure_hdd") as svc:
        _serve_some(svc, D.keys)
        d = detect_drift(svc, min_queries=256).to_dict()
    json.dumps(d, allow_nan=False)                 # strict-JSON trendable
    assert d["action"] == "retune" and d["ratio"] > 1.25


def test_drift_symmetric_on_faster_tier():
    # a tier that got FASTER is drift too: the optimum moves either way
    s = ServeStats(queries=1000, modeled_seconds=1.0,
                   walk_modeled_seconds=1.0)
    rep = drift_from_stats(s, recorded_cost=10.0, min_queries=100)
    assert rep.ratio < 1 / 1.25 and rep.drifted and rep.action == "retune"


# ---------------------------------------------------------------------------
# warm-started retune
# ---------------------------------------------------------------------------
def _designs_equal(a, b) -> bool:
    if len(a.layers) != len(b.layers):
        return False
    for la, lb in zip(a.layers, b.layers):
        if la.kind != lb.kind:
            return False
        fields = (("piece_keys", "piece_pos", "node_piece_off")
                  if la.kind == "step"
                  else ("node_keys", "x1", "y1", "m", "delta"))
        if not all(np.array_equal(getattr(la, f), getattr(lb, f))
                   for f in fields):
            return False
    return True


@pytest.mark.parametrize("strategy", ["airtune", "beam"])
def test_warm_retune_bit_identical_and_cheaper(tuned, strategy):
    D, idx, path = tuned
    new_tier = PROFILES["azure_hdd"]
    cold = idx.retune(new_tier, strategy=strategy).build()
    warm = idx.retune(new_tier, warm_start=True, strategy=strategy).build()
    # same optimum (warm start is memoization + seed vertices)...
    assert _designs_equal(cold.result.design, warm.result.design)
    assert warm.cost == cold.cost
    # ...for measurably less work
    assert warm.stats.layers_reused > cold.stats.layers_reused
    assert warm.stats.layers_built < cold.stats.layers_built
    assert warm.stats.layers_seeded > 0


def test_warm_retune_from_disk_recovers_seed(tuned):
    D, idx, path = tuned
    opened = Index.open(path, data=D)
    cold = opened.retune("azure_hdd").build()
    warm = opened.retune("azure_hdd", warm_start=True).build()
    assert _designs_equal(cold.result.design, warm.result.design)
    assert warm.cost == cold.cost
    assert warm.stats.layers_seeded > 0
    assert warm.stats.layers_reused > cold.stats.layers_reused
    assert warm.stats.layers_built < cold.stats.layers_built


def test_recover_seed_layers_canonicalizes_disk_designs():
    # the file format drops step node grouping and band clamp_lo; recovery
    # must restore BOTH bit-exactly, per the recorded builder discipline
    import tempfile

    from repro.api.index import recover_seed_layers
    from repro.core import IndexDesign, outline, write_index
    from repro.core.builders import LayerBuilder
    from repro.core.serialize import materialize_design

    keys = make_keys("books", 30_000, seed=4)
    D = KeyPositions.fixed_record(keys, 16)
    b1 = LayerBuilder(kind="gband", lam=2**9)
    b2 = LayerBuilder(kind="gstep", lam=2**7, p=8)
    l1 = b1(D)
    l2 = b2(outline(l1, D))
    path = os.path.join(tempfile.mkdtemp(), "two.air")
    write_index(path, IndexDesign(layers=(l1, l2), data=D), page_bytes=1024)
    disk = materialize_design(path, D).layers
    assert disk[0].clamp_lo != l1.clamp_lo or l1.clamp_lo == 0
    assert len(disk[1].node_piece_off) != len(l2.node_piece_off) \
        or l2.n_pieces <= b2.p
    seed = recover_seed_layers((b1.name, b2.name), disk, [b1, b2], D)
    assert [n for n, _ in seed] == [b1.name, b2.name]
    r1, r2 = (layer for _, layer in seed)
    for f in ("node_keys", "x1", "y1", "m", "delta"):
        assert np.array_equal(getattr(r1, f), getattr(l1, f))
    assert (r1.clamp_lo, r1.clamp_hi) == (l1.clamp_lo, l1.clamp_hi)
    for f in ("piece_keys", "piece_pos", "node_piece_off"):
        assert np.array_equal(getattr(r2, f), getattr(l2, f))
    # an unknown builder name stops the chain (no poisoned cache entries)
    partial = recover_seed_layers((b1.name, "ThirdParty(9)"), disk,
                                  [b1, b2], D)
    assert [n for n, _ in partial] == [b1.name]


def test_warm_seed_survives_band_and_multilayer_designs():
    # a stacked step<-band<-step design round-trips through the file into
    # canonical seed layers (regrouped steps, re-clamped bands)
    keys = make_keys("fb", 40_000, seed=9)
    D = KeyPositions.fixed_record(keys, 16)
    import tempfile
    design = demo_serving_design(D)
    idx = Index.from_design(design, spec=TuneSpec(page_bytes=1024),
                            profile="azure_ssd")
    path = os.path.join(tempfile.mkdtemp(), "multi.air")
    idx.save(path)
    opened = Index.open(path, data=D)
    spec = (opened.spec or TuneSpec()).validate()
    seed = opened._warm_seed_layers(D, spec)
    # demo designs are built manually (strategy="manual"): no builder
    # provenance is recorded, so recovery must yield no seed — and a warm
    # retune must still work, falling back to a plain search
    assert seed == []
    warm = opened.retune("azure_hdd", warm_start=True,
                         lam_high=2**14, lam_base=4.0).build()
    cold = opened.retune("azure_hdd",
                         lam_high=2**14, lam_base=4.0).build()
    assert _designs_equal(cold.result.design, warm.result.design)


def test_layer_cache_entry_cap_bounds_retune_loops():
    # a long-running observe→retune loop shares one LayerCache across
    # generations; max_entries must bound it (eviction = rebuild later,
    # never an error) while results stay identical to unbounded search
    from repro.core import PROFILES as P
    from repro.core.airtune import airtune as run_airtune
    from repro.core.sweep import LayerCache
    keys = make_keys("gmm", 20_000, seed=3)
    D = KeyPositions.fixed_record(keys, 16)
    from repro.core import make_builders
    builders = make_builders(lam_low=2**8, lam_high=2**14, base=2.0)
    free = run_airtune(D, P["azure_ssd"], builders, k=3)
    tiny = LayerCache(max_entries=4)
    for tier in ("azure_ssd", "azure_hdd", "azure_ssd"):
        res = run_airtune(D, P[tier], builders, k=3, layer_cache=tiny)
        assert len(tiny) <= 4
        if tier == "azure_ssd":
            assert res.cost == free.cost    # eviction never changes results


def test_retune_shares_layer_cache_across_tiers(tuned):
    # the parent Index retains its LayerCache: two successive warm retunes
    # to different tiers reuse each other's builds (profile-keyed scores
    # can never alias — see repro.core.sweep.LayerCache)
    D, idx, path = tuned
    w1 = idx.retune("azure_hdd", warm_start=True).build()
    w2 = idx.retune("azure_nfs", warm_start=True).build()
    assert w2.stats.layers_built <= w1.stats.layers_built
    assert w2.stats.layers_reused >= w1.stats.layers_reused
    # both agree with their cold searches
    assert w1.cost == idx.retune("azure_hdd").build().cost
    assert w2.cost == idx.retune("azure_nfs").build().cost
