"""Hypothesis property tests for the baseline-family invariants (§7.1 /
Appendix B): pgm's ε bound, btree's page discipline, rmi_leaf's monotone
root routing.  Non-property baseline coverage (registration, wrapper
parity, in-search dominance) lives in test_core_airtune.py so it runs
without the optional hypothesis dependency."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="optional test dep (pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import KeyPositions
from repro.core.baselines import (BTREE_PAGE_BYTES, PGM_EPS_GRID,
                                  PGM_RECORD_BYTES, btree_fanout, build_rmi,
                                  build_rmi_leaf, rmi_slot_starts)
from repro.core.nodes import STEP_PIECE_BYTES
from repro.core.registry import BUILDER_FAMILIES


def _random_data(data, n_max=400, key_space=2**40, record=PGM_RECORD_BYTES):
    n = data.draw(st.integers(2, n_max))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    keys = np.unique(rng.integers(0, key_space, n).astype(np.uint64))
    return KeyPositions.fixed_record(keys, record)


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_pgm_layer_respects_eps_bound(data):
    """Every pgm layer keeps |ŷ(x) − y(x)| ≤ ε on ALL indexed keys: band
    half-width δ ≤ ε (+fit safety) and Eq. (1) validity."""
    D = _random_data(data)
    eps_records = data.draw(st.sampled_from(PGM_EPS_GRID))
    eps_bytes = float(eps_records * PGM_RECORD_BYTES)
    layer = BUILDER_FAMILIES.get("pgm")(D, eps_bytes, 0)
    layer.validate_against(D)                      # ŷ ⊇ y, Eq. (1)
    # greedy feasibility admits a group only when resid + safety ≤ ε;
    # the built δ adds ≤ 2 bytes of rint/safety slack on top
    assert np.all(layer.delta <= eps_bytes + 2.0)
    # the same bound in the paper's units: error ≤ ε records (+slack)
    lo, hi = layer.predict(D.keys)
    mid_pred = 0.5 * (lo.astype(np.float64) + hi.astype(np.float64))
    err_records = np.abs(mid_pred - D.mid_f) / PGM_RECORD_BYTES
    assert np.all(err_records <= eps_records + 1.0)


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_btree_node_discipline(data):
    """btree nodes follow the page discipline: fanout ≤ p(λ) and node
    size ≤ λ for every page size on the grid."""
    D = _random_data(data)
    lam = float(data.draw(st.sampled_from([512, 1024, 4096, 16384])))
    layer = BUILDER_FAMILIES.get("btree")(D, lam, 0)
    layer.validate_against(D)
    p = btree_fanout(lam)
    pieces = np.diff(layer.node_piece_off)
    assert np.all(pieces >= 1) and np.all(pieces <= p)
    assert np.all(layer.node_sizes() <= lam)
    # the default page reproduces the paper's 255-fanout B-TREE node
    assert btree_fanout(BTREE_PAGE_BYTES) == 255
    assert 255 * STEP_PIECE_BYTES < BTREE_PAGE_BYTES


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_rmi_leaf_valid_and_root_monotone(data):
    """rmi_leaf layers are valid; the two-layer RMI's CDF root routes
    monotonically over the whole key range."""
    D = _random_data(data)
    n_models = data.draw(st.integers(1, 64))
    leaf = build_rmi_leaf(D, n_models)
    leaf.validate_against(D)
    assert np.all(np.diff(leaf.node_keys.astype(np.int64)) > 0)
    n, bounds, gid, starts = rmi_slot_starts(D, n_models)
    assert leaf.n_nodes == len(starts) <= n
    assert np.all(np.diff(gid) >= 0)               # slot routing monotone
    # the materialized root band is monotone non-decreasing in the key
    design = build_rmi(D, n_models)
    root = design.layers[1]
    assert float(root.m[0]) >= 0.0
    qs = np.linspace(float(D.keys[0]), float(D.keys[-1]),
                     257).astype(np.uint64)
    lo, hi = root.predict(qs)
    assert np.all(np.diff(lo) >= 0) and np.all(np.diff(hi) >= 0)
